"""Sparse (ELL) contraction kernels: SpMM for the one-hot/Criteo tier.

The IRLS sinks over a sparse design matrix are the same contractions
`gram.py` / `weighted_gram.py` compute — XᵀX, XᵀY, XᵀWX — but the operand
arrives as an ELL slab (core/sparse.SparseBlock: ``cols`` int32 and
``vals`` of shape (rows, kmax), kmax ≪ ncol).  The FlashR story is that
these workloads are I/O bound: what matters is that HBM (≙ SSD) traffic is
nnz-proportional, 2·kmax scalars per row instead of ncol.

Inside the kernel each VMEM-resident slab is scatter-expanded to a dense
(block_rows, p) tile —

    rows = broadcasted_iota(...);  tile = zeros.at[rows, cols].add(vals)

— and contracted on the MXU with ``dot_general``, exactly like the dense
kernels.  The expansion never exists in HBM; padding entries are
(col=0, val=0), neutral under scatter-ADD and sum-product contraction
(same zero-padding argument as `gram.py`).  Grid, accumulator residency
and writeback follow the `weighted_gram.py` template: 1-D grid over row
blocks, (p, p) f32 accumulator in VMEM scratch for the whole sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, pad_rows, pick_block_rows


def _scatter_tile(cols, vals, ncol: int):
    """ELL slab → dense f32 (rows, ncol) tile, in-register/VMEM only."""
    rows = jax.lax.broadcasted_iota(jnp.int32, cols.shape, 0)
    tile = jnp.zeros((cols.shape[0], ncol), jnp.float32)
    return tile.at[rows, cols].add(vals.astype(jnp.float32))


def _spmm_block_rows(n: int, kmax: int, p: int, dtype) -> int:
    # Live tiles per block: the slab (2 arrays, kmax wide) plus the
    # scatter-expanded (rows, p) tile — budget on the widest.
    return pick_block_rows(n, max(p, 2 * kmax), dtype, n_live=2)


def _spmm_gram_kernel(cols_ref, vals_ref, g_ref, acc, *, ncol):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = _scatter_tile(cols_ref[...], vals_ref[...], ncol)
    acc[...] += jax.lax.dot_general(
        x, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        g_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("ncol", "block_rows",
                                             "interpret"))
def spmm_gram(cols, vals, *, ncol: int, block_rows: int = 0,
              interpret: bool | None = None):
    """G = XᵀX for sparse ELL X (n rows, ncol logical columns)."""
    interpret = default_interpret() if interpret is None else interpret
    n, kmax = cols.shape
    if not block_rows:
        block_rows = _spmm_block_rows(n, kmax, ncol, vals.dtype)
    cp, _ = pad_rows(cols, block_rows, value=0)
    vp, _ = pad_rows(vals, block_rows, value=0)
    grid = (cp.shape[0] // block_rows,)
    kernel = functools.partial(_spmm_gram_kernel, ncol=ncol)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, kmax), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, kmax), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ncol, ncol), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ncol, ncol), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ncol, ncol), jnp.float32)],
        interpret=interpret,
    )(cp, vp)


def _spmm_xty_kernel(cols_ref, vals_ref, y_ref, g_ref, acc, *, ncol):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = _scatter_tile(cols_ref[...], vals_ref[...], ncol)
    acc[...] += jax.lax.dot_general(
        x, y_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        g_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("ncol", "block_rows",
                                             "interpret"))
def spmm_xty(cols, vals, y, *, ncol: int, block_rows: int = 0,
             interpret: bool | None = None):
    """XᵀY for sparse ELL X and row-aligned dense Y (n, q); (ncol, q) f32."""
    interpret = default_interpret() if interpret is None else interpret
    n, kmax = cols.shape
    q = y.shape[1]
    if not block_rows:
        block_rows = _spmm_block_rows(n, kmax, max(ncol, q), vals.dtype)
    cp, _ = pad_rows(cols, block_rows, value=0)
    vp, _ = pad_rows(vals, block_rows, value=0)
    yp, _ = pad_rows(y, block_rows)
    grid = (cp.shape[0] // block_rows,)
    kernel = functools.partial(_spmm_xty_kernel, ncol=ncol)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, kmax), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, kmax), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, q), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ncol, q), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ncol, q), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ncol, q), jnp.float32)],
        interpret=interpret,
    )(cp, vp, yp)


def _spmm_wgram_kernel(cols_ref, vals_ref, w_ref, g_ref, acc, *, ncol):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = _scatter_tile(cols_ref[...], vals_ref[...], ncol)
    w = w_ref[...].astype(jnp.float32)  # (block_rows, 1), broadcasts per row
    acc[...] += jax.lax.dot_general(
        x * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        g_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("ncol", "block_rows",
                                             "interpret"))
def spmm_wgram(cols, vals, w, *, ncol: int, block_rows: int = 0,
               interpret: bool | None = None):
    """G = XᵀWX for sparse ELL X and per-row weights w (n,) or (n, 1) —
    the sparse IRLS hot spot.  Zero-padded w rows are neutral."""
    interpret = default_interpret() if interpret is None else interpret
    n, kmax = cols.shape
    w = w.reshape(n, 1)
    if not block_rows:
        block_rows = _spmm_block_rows(n, kmax, ncol, vals.dtype)
    cp, _ = pad_rows(cols, block_rows, value=0)
    vp, _ = pad_rows(vals, block_rows, value=0)
    wp, _ = pad_rows(w, block_rows)
    grid = (cp.shape[0] // block_rows,)
    kernel = functools.partial(_spmm_wgram_kernel, ncol=ncol)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, kmax), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, kmax), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ncol, ncol), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ncol, ncol), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ncol, ncol), jnp.float32)],
        interpret=interpret,
    )(cp, vp, wp)
