"""Tall-skinny Gram kernel: G = XᵀX with streaming row blocks.

The hot inner product of correlation and SVD (paper §IV-A): contract the
long dimension of a TAS matrix.  The paper hands this to BLAS; on TPU the
analog is feeding the MXU from VMEM-resident tiles while the (p, p)
accumulator never leaves VMEM for the whole sweep — one read of X, one
write of G.

Grid: 1-D over row blocks; f32 accumulation regardless of input dtype
(bf16 in → f32 acc, the MXU-native mixed-precision mode).
Also provides ``xty`` (Xᵀ·Y for a second tall matrix) — the GMM M-step
moment sink (X⊙r)ᵀX shares this code path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, pad_rows, pick_block_rows


def _gram_kernel(x_ref, g_ref, acc, *, n_rows, block_rows):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...]
    # Padding rows are zero — harmless for a sum-product contraction.
    acc[...] += jax.lax.dot_general(
        x, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        g_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gram(x, *, block_rows: int = 0, interpret: bool | None = None):
    """G = XᵀX for tall (n, p) X; returns (p, p) float32."""
    interpret = default_interpret() if interpret is None else interpret
    n, p = x.shape
    if not block_rows:
        block_rows = pick_block_rows(n, p, x.dtype, n_live=2)
    xp, _ = pad_rows(x, block_rows)  # zero pad: neutral for sum-product
    grid = (xp.shape[0] // block_rows,)
    kernel = functools.partial(_gram_kernel, n_rows=n, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((p, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        interpret=interpret,
    )(xp)


def _xty_kernel(x_ref, y_ref, g_ref, acc):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        g_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def xty(x, y, *, block_rows: int = 0, interpret: bool | None = None):
    """XᵀY for row-aligned tall X (n, p) and Y (n, q); returns (p, q) f32."""
    interpret = default_interpret() if interpret is None else interpret
    n, p = x.shape
    _, q = y.shape
    if not block_rows:
        block_rows = pick_block_rows(n, max(p, q), x.dtype, n_live=3)
    xp, _ = pad_rows(x, block_rows)
    yp, _ = pad_rows(y, block_rows)
    grid = (xp.shape[0] // block_rows,)
    return pl.pallas_call(
        _xty_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, q), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((p, q), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, q), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, q), jnp.float32)],
        interpret=interpret,
    )(xp, yp)
