"""Fused Lloyd-step kernel: distances → argmin → cluster stats, one pass.

The paper's k-means iteration is its marquee fusion demo: inner.prod with
the (squared-diff, sum) semiring, which.min, groupby.row(sum) and the
objective all stream together (core/algorithms/kmeans.py builds the same
DAG).  Here the whole fused group is ONE Pallas kernel:

  per VMEM-resident row block X_b (bm, p), centers C (k, p) resident:
    D    = ‖X_b‖² - 2 X_b Cᵀ + ‖C‖²        (MXU matmul + VPU epilogue)
    lab  = argmin_k D                       (VPU)
    H    = onehot(lab)                      (VPU)
    sums += Hᵀ X_b                          (MXU)   — groupby.row(sum)
    cnts += Σ H                             (VPU)   — table()
    wss  += Σ min_k D                       (VPU)   — objective
    labels_b written out                    (HBM, bm ints)

X is read once; everything else lives in VMEM scratch until the final
writeback.  k and p are small (paper: k ≤ 64, p ≤ 512) so C, sums (k, p)
and the D tile (bm, k) all fit comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, pad_rows, pick_block_rows


def _kernel(x_ref, c_ref, nrows_ref, lab_ref, sums_ref, cnts_ref, wss_ref,
            acc_sums, acc_cnts, acc_wss, *, block_rows):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_sums[...] = jnp.zeros_like(acc_sums)
        acc_cnts[...] = jnp.zeros_like(acc_cnts)
        acc_wss[...] = jnp.zeros_like(acc_wss)

    x = x_ref[...].astype(jnp.float32)          # (bm, p)
    c = c_ref[...].astype(jnp.float32)          # (k, p)

    # Squared Euclidean distances via the inner-product expansion so the MXU
    # does the heavy lifting (the paper's BLAS dispatch, TPU-style).
    x2 = (x * x).sum(axis=1, keepdims=True)                       # (bm, 1)
    c2 = (c * c).sum(axis=1, keepdims=True).T                     # (1, k)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bm, k)
    d = x2 - 2.0 * xc + c2

    row_ids = jax.lax.broadcasted_iota(jnp.int32, d.shape, 0) + i * block_rows
    valid = row_ids[:, 0] < nrows_ref[0]

    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    lab_ref[...] = lab
    k = c.shape[0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
              == lab[:, None]).astype(jnp.float32)
    onehot = jnp.where(valid[:, None], onehot, 0.0)

    acc_sums[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_cnts[...] += onehot.sum(axis=0)
    mind = jnp.where(valid, d.min(axis=1), 0.0)
    acc_wss[...] += mind.sum()[None]

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        sums_ref[...] = acc_sums[...]
        cnts_ref[...] = acc_cnts[...]
        wss_ref[...] = acc_wss[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def kmeans_assign(x, centers, *, block_rows: int = 0,
                  interpret: bool | None = None):
    """One fused Lloyd step.

    Args:   x (n, p) float; centers (k, p) float.
    Returns (labels (n,) int32, sums (k, p) f32, counts (k,) f32, wss (1,) f32).
    """
    interpret = default_interpret() if interpret is None else interpret
    n, p = x.shape
    k = centers.shape[0]
    if not block_rows:
        block_rows = pick_block_rows(n, p + k, x.dtype, n_live=3)
    xp, n_true = pad_rows(x, block_rows)
    grid = (xp.shape[0] // block_rows,)
    nrows = jnp.full((1,), n_true, jnp.int32)

    kernel = functools.partial(_kernel, block_rows=block_rows)
    lab, sums, cnts, wss = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
            pl.BlockSpec((k, p), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((k, p), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((k, p), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k, p), jnp.float32),
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, centers, nrows)
    return lab[:n], sums, cnts, wss
