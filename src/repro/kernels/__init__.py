"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling, a jit'd
wrapper in ops.py, and a pure-jnp oracle in ref.py (tests assert allclose
over shape/dtype sweeps in interpret mode).
"""
from . import ops, ref

__all__ = ["ops", "ref"]
