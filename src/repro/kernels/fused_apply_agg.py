"""Fused apply→aggregate streaming kernel — the GenOps cache-fuse hot-spot.

This is the paper's statistical-summary workload (§IV-A) as ONE Pallas
kernel, generalized: a tall matrix streams HBM→VMEM block-by-block and an
arbitrary set of *chains* — each a pipeline of unary VUDFs followed by a
column aggregation — updates from the same resident tile.  The elementwise
"apply" stages (x², |x|, √x, casts, …) never touch HBM — exactly the
paper's CPU-cache operation fusion, restated for the HBM→VMEM tier.

``fused_apply_agg(x, chains)`` takes a static chain spec

    chains = (((unary_name, ...), agg_name[, acc_dtype]), ...)

where each unary name resolves in the core VUDF registry (core/vudf.py),
agg_name ∈ {sum, min, max, count, count_nonzero}, and the optional
per-chain ``acc_dtype`` ('float32' | 'int32', default = the call-level
``acc_dtype`` parameter) selects the VMEM accumulator element type.  An
int32 accumulator makes integer sums/counts EXACT (a float32 accumulator
loses integer exactness past 2²⁴), which is what lets the engine's pallas
lowering claim integer apply→agg chains and chains containing lazy cast
nodes instead of falling back to the generic trace (ROADMAP item).  The
engine's pallas lowering (core/lowering.py) compiles eligible agg.col sink
segments sharing one source into a single call, so N statistics cost one
read of X.  ``fused_summary`` is the paper's six-statistic instance.

Grid: 1-D over row blocks (the processor-level partition axis).
Accumulators live in VMEM scratch for the whole grid sweep (TPU grids
execute sequentially per core), initialized at step 0 and written back at
the last step — the same identity→update→combine contract as core/dag.py
sinks.

Rows are padded to the block multiple with neutral values handled by
masking inside the kernel (min/max need ±inf / int extrema, so padding
cannot be plain zeros).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, pad_rows, pick_block_rows

#: Aggregations the chain kernel can accumulate in a VMEM scratch register.
CHAIN_AGGS = ("sum", "min", "max", "count", "count_nonzero")

#: Unary VUDFs safe to evaluate on a VMEM tile inside the kernel body.
#: The cast family keeps lazily-inserted dtype conversions (paper §III-D)
#: inside the kernel so mixed-dtype chains stay eligible.
CHAIN_UNARIES = ("identity", "abs", "sq", "sqrt", "exp", "log", "log1p",
                 "neg", "sigmoid", "floor", "ceil", "round", "sign",
                 "cast_float32", "cast_int32", "cast_bfloat16")

#: Accumulator dtypes a chain may request.
CHAIN_ACC_DTYPES = ("float32", "int32")

#: fused_summary's chain spec: (sum, sum-of-squares, min, max, L1, nnz).
SUMMARY_CHAINS = (((), "sum"), (("sq",), "sum"), ((), "min"), ((), "max"),
                  (("abs",), "sum"), ((), "count_nonzero"))


def _unary_fn(name):
    from ..core import vudf as vudf_mod  # deferred: keep kernels importable alone
    return vudf_mod.unary(name).fn


def _acc_extreme(dtype, *, biggest: bool):
    dt = jnp.dtype(dtype)
    if dt.kind == "f":
        return jnp.inf if biggest else -jnp.inf
    info = np.iinfo(dt.name)
    return info.max if biggest else info.min


def normalize_chains(chains, acc_dtype: str = "float32"):
    """Canonicalize a chain spec to ((unaries, agg, acc_dtype), ...);
    2-tuples take the call-level default accumulator dtype."""
    out = []
    for chain in chains:
        if len(chain) == 2:
            unaries, agg = chain
            acc = acc_dtype
        else:
            unaries, agg, acc = chain
        out.append((tuple(unaries), agg, acc))
    return tuple(out)


def _chain_kernel(x_ref, nrows_ref, *refs, chains, block_rows):
    n_out = len(chains)
    out_refs, accs = refs[:n_out], refs[n_out:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        for (_, agg, _), acc in zip(chains, accs):
            if agg == "min":
                acc[...] = jnp.full_like(
                    acc, _acc_extreme(acc.dtype, biggest=True))
            elif agg == "max":
                acc[...] = jnp.full_like(
                    acc, _acc_extreme(acc.dtype, biggest=False))
            else:
                acc[...] = jnp.zeros_like(acc)

    x = x_ref[...]
    # Rows beyond the true length are padding: mask them out of every stat.
    row_ids = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + i * block_rows
    valid = row_ids < nrows_ref[0]

    for (unaries, agg, _), acc in zip(chains, accs):
        at = acc.dtype
        # Float accumulators evaluate the chain in f32 (the MXU/VPU-native
        # mode); int accumulators keep the source's integer algebra exact.
        v = x.astype(jnp.float32) if jnp.dtype(at).kind == "f" else x
        for u in unaries:
            v = _unary_fn(u)(v)
        if agg == "sum":
            acc[...] += jnp.where(valid, v, 0).astype(at).sum(axis=0)
        elif agg == "count":
            acc[...] += valid.astype(at).sum(axis=0)
        elif agg == "count_nonzero":
            acc[...] += (valid & (v != 0)).astype(at).sum(axis=0)
        elif agg == "min":
            big = _acc_extreme(at, biggest=True)
            acc[...] = jnp.minimum(
                acc[...], jnp.where(valid, v.astype(at), big).min(axis=0))
        elif agg == "max":
            small = _acc_extreme(at, biggest=False)
            acc[...] = jnp.maximum(
                acc[...], jnp.where(valid, v.astype(at), small).max(axis=0))

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        for o, acc in zip(out_refs, accs):
            o[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("chains", "acc_dtype",
                                             "block_rows", "interpret"))
def fused_apply_agg(x, chains, *, acc_dtype: str = "float32",
                    block_rows: int = 0, interpret: bool | None = None):
    """Column statistics of a tall (n, p) matrix in one HBM pass.

    ``chains``: static tuple of ``((unary_name, ...), agg_name)`` or
    ``((unary_name, ...), agg_name, acc_dtype)`` entries; ``acc_dtype`` is
    the default accumulator element type for the 2-tuple form.
    Returns one (p,) array per chain, in that chain's accumulator dtype.
    """
    if acc_dtype not in CHAIN_ACC_DTYPES:
        raise ValueError(f"unsupported accumulator dtype {acc_dtype!r}; "
                         f"have {CHAIN_ACC_DTYPES}")
    chains = normalize_chains(chains, acc_dtype)
    for unaries, agg, acc in chains:
        if agg not in CHAIN_AGGS:
            raise ValueError(f"unsupported chain aggregation {agg!r}")
        if acc not in CHAIN_ACC_DTYPES:
            raise ValueError(f"unsupported accumulator dtype {acc!r}; "
                             f"have {CHAIN_ACC_DTYPES}")
        for u in unaries:
            if u not in CHAIN_UNARIES:
                raise ValueError(f"unsupported chain unary {u!r}")
    interpret = default_interpret() if interpret is None else interpret
    n, p = x.shape
    if not block_rows:
        block_rows = pick_block_rows(n, p, x.dtype, n_live=2)
    xp, n_true = pad_rows(x, block_rows)
    grid = (xp.shape[0] // block_rows,)
    nrows = jnp.full((1,), n_true, jnp.int32)

    kernel = functools.partial(_chain_kernel, chains=chains,
                               block_rows=block_rows)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[pl.BlockSpec((p,), lambda i: (0,))] * len(chains),
        out_shape=[jax.ShapeDtypeStruct((p,), jnp.dtype(acc))
                   for _, _, acc in chains],
        scratch_shapes=[pltpu.VMEM((p,), jnp.dtype(acc))
                        for _, _, acc in chains],
        interpret=interpret,
    )(xp, nrows)
    return tuple(outs)


def fused_summary(x, *, block_rows: int = 0, interpret: bool | None = None):
    """Column statistics of a tall (n, p) matrix in one HBM pass.

    Returns (sum, sumsq, min, max, l1, nnz) each of shape (p,), float32.
    """
    return fused_apply_agg(x, SUMMARY_CHAINS, block_rows=block_rows,
                           interpret=interpret)
