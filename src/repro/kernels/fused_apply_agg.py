"""Fused apply→aggregate streaming kernel — the GenOps cache-fuse hot-spot.

This is the paper's statistical-summary workload (§IV-A) as ONE Pallas
kernel: a tall matrix streams HBM→VMEM block-by-block and every column
statistic (sum, sum-of-squares, min, max, L1, nnz) updates from the same
resident tile.  The elementwise "apply" stage (here x², |x|, x≠0) never
touches HBM — exactly the paper's CPU-cache operation fusion, restated for
the HBM→VMEM tier.

Grid: 1-D over row blocks (the I/O-level partition axis).  Accumulators
live in VMEM scratch for the whole grid sweep (TPU grids execute
sequentially per core), initialized at step 0 and written back at the last
step — the same identity→update→combine contract as core/dag.py sinks.

Rows are padded to the block multiple with neutral values handled by
masking inside the kernel (min/max need ±inf, so padding cannot be plain
zeros).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, pad_rows, pick_block_rows


def _kernel(x_ref, nrows_ref, sum_ref, sq_ref, mn_ref, mx_ref, l1_ref, nnz_ref,
            acc_sum, acc_sq, acc_mn, acc_mx, acc_l1, acc_nnz, *, block_rows):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_sum[...] = jnp.zeros_like(acc_sum)
        acc_sq[...] = jnp.zeros_like(acc_sq)
        acc_mn[...] = jnp.full_like(acc_mn, jnp.inf)
        acc_mx[...] = jnp.full_like(acc_mx, -jnp.inf)
        acc_l1[...] = jnp.zeros_like(acc_l1)
        acc_nnz[...] = jnp.zeros_like(acc_nnz)

    x = x_ref[...].astype(jnp.float32)
    # Rows beyond the true length are padding: mask them out of every stat.
    row_ids = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + i * block_rows
    valid = row_ids < nrows_ref[0]
    zero = jnp.zeros_like(x)

    xz = jnp.where(valid, x, zero)
    acc_sum[...] += xz.sum(axis=0)
    acc_sq[...] += (xz * xz).sum(axis=0)
    acc_l1[...] += jnp.abs(xz).sum(axis=0)
    acc_nnz[...] += jnp.where(valid & (x != 0), 1.0, 0.0).sum(axis=0)
    acc_mn[...] = jnp.minimum(acc_mn[...],
                              jnp.where(valid, x, jnp.inf).min(axis=0))
    acc_mx[...] = jnp.maximum(acc_mx[...],
                              jnp.where(valid, x, -jnp.inf).max(axis=0))

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        sum_ref[...] = acc_sum[...]
        sq_ref[...] = acc_sq[...]
        mn_ref[...] = acc_mn[...]
        mx_ref[...] = acc_mx[...]
        l1_ref[...] = acc_l1[...]
        nnz_ref[...] = acc_nnz[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_summary(x, *, block_rows: int = 0, interpret: bool | None = None):
    """Column statistics of a tall (n, p) matrix in one HBM pass.

    Returns (sum, sumsq, min, max, l1, nnz) each of shape (p,), float32.
    """
    interpret = default_interpret() if interpret is None else interpret
    n, p = x.shape
    if not block_rows:
        block_rows = pick_block_rows(n, p, x.dtype, n_live=2)
    xp, n_true = pad_rows(x, block_rows)
    grid = (xp.shape[0] // block_rows,)
    nrows = jnp.full((1,), n_true, jnp.int32)

    col = jax.ShapeDtypeStruct((p,), jnp.float32)
    kernel = functools.partial(_kernel, block_rows=block_rows)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[pl.BlockSpec((p,), lambda i: (0,))] * 6,
        out_shape=[col] * 6,
        scratch_shapes=[pltpu.VMEM((p,), jnp.float32)] * 6,
        interpret=interpret,
    )(xp, nrows)
    return outs
