"""Public jit'd wrappers over the Pallas kernels.

Call sites (core GenOps fast paths, the LM stack, benchmarks) import from
here; each wrapper dispatches Pallas-on-TPU / Pallas-interpret-on-CPU and
exposes the pure-jnp oracle as a `*_ref` fallback so the same call site can
A/B the kernel against XLA's own fusion (benchmarks/kernel_bench.py).
"""
from __future__ import annotations

import jax

from . import ref
from .common import default_interpret
from .flash_attention import flash_attention
from .fused_apply_agg import fused_apply_agg, fused_summary
from .gram import gram, xty
from .kmeans_assign import kmeans_assign
from .weighted_gram import wgram

__all__ = [
    "fused_apply_agg", "fused_summary", "gram", "xty", "wgram",
    "kmeans_assign", "flash_attention", "attention", "ref",
    "default_interpret",
]


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              impl: str = "auto", **kw):
    """Attention entry point for the LM stack.

    impl='pallas' — the Flash kernel (TPU, or interpret on CPU: exact but
    slow, test-only); impl='ref' — jnp oracle (XLA fuses it; used for CPU
    dry-runs/training in this container); 'auto' — pallas on TPU else ref.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, scale=scale, **kw)
    return ref.attention_ref(q, k, v, causal=causal, scale=scale)
