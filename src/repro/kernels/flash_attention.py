"""Blockwise-softmax (Flash) attention kernel for the LM stack.

The LM architectures' prefill hot-spot.  FlashMatrix's two-level insight
applies directly: the (S, S) score matrix is a *virtual matrix* that must
never be materialized in HBM; only VMEM-resident (bq, bk) tiles of it ever
exist, with the online-softmax running (m, l) statistics playing the role
of the streaming aggregation VUDF's accumulator (same identity → update →
combine contract as core/dag.py sinks — logsumexp is literally the
``logsumexp`` AggVUDF).

Grid: (batch·heads, q_blocks, kv_blocks), sequential on TPU per core; the
kv axis is innermost so the (m, l, acc) scratch carries across kv blocks
and writes the output tile once at the last kv step.

Causal masking uses absolute row/col ids; fully-masked tiles are skipped
(the index-map trick would need a dynamic grid — masking with a finite
NEG_INF keeps the kernel robust in interpret mode and on Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, round_up

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, causal, bq, bk, seq_len):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_ids = iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_ids = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_ids < seq_len  # kv padding
    if causal:
        mask = mask & (q_ids >= k_ids)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _writeback():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """Blockwise attention over (BH, S, D) tensors.

    GQA is handled by the caller (repeat/reshape of KV heads); this kernel
    sees matched head counts.  Returns (BH, S, D) in q.dtype.
    """
    interpret = default_interpret() if interpret is None else interpret
    bh, s_len, d = q.shape
    skv = k.shape[1]
    scale = (d ** -0.5) if scale is None else scale
    bq = min(bq, round_up(s_len, 8))
    bk = min(bk, round_up(skv, 8))

    def pad_seq(x, blk):
        target = round_up(x.shape[1], blk)
        if target == x.shape[1]:
            return x
        return jnp.pad(x, ((0, 0), (0, target - x.shape[1]), (0, 0)))

    qp, kp, vp = pad_seq(q, bq), pad_seq(k, bk), pad_seq(v, bk)
    grid = (bh, qp.shape[1] // bq, kp.shape[1] // bk)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, seq_len=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s_len]
