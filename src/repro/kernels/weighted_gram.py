"""Weighted Gram kernel: G = XᵀWX for a diagonal weight vector w.

The IRLS hot spot (algorithms/glm.py): every GLM Newton step contracts the
long dimension of X against itself under per-row weights,

    G = Σ_i w_i · x_i x_iᵀ        (p × p, f32 accumulation)

which in R is ``crossprod(X * w, X)``.  The engine's pallas backend
(``core.lowering._match_weighted_gram``) recognizes the fused
``mapply.col(X, w, mul) → inner.prod(mul, sum)`` contraction segment and
lowers it onto this kernel, so the elementwise reweighting never exists in
HBM — X and w stream through VMEM once and only the (p, p) accumulator
persists across the grid sweep, exactly like `gram.py`.

Grid: 1-D over row blocks; zero row padding is neutral (padded w rows are
zero, so their outer products vanish).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import default_interpret, pad_rows, pick_block_rows


def _wgram_kernel(x_ref, w_ref, g_ref, acc):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # (block_rows, 1), broadcasts per row
    acc[...] += jax.lax.dot_general(
        x * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _writeback():
        g_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wgram(x, w, *, block_rows: int = 0, interpret: bool | None = None):
    """G = XᵀWX for tall (n, p) X and per-row weights w (n,) or (n, 1).

    Returns (p, p) float32.  One HBM read of X and w; the reweighted rows
    exist only inside the VMEM tile.
    """
    interpret = default_interpret() if interpret is None else interpret
    n, p = x.shape
    w = w.reshape(n, 1)
    if not block_rows:
        block_rows = pick_block_rows(n, p, x.dtype, n_live=3)
    xp, _ = pad_rows(x, block_rows)  # zero pad: neutral under zero weights
    wp, _ = pad_rows(w, block_rows)
    grid = (xp.shape[0] // block_rows,)
    return pl.pallas_call(
        _wgram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((p, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
