"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling) and
validate on CPU via ``interpret=True`` — the kernel body executes in Python
so the BlockSpec/grid logic is what is under test.  ``default_interpret()``
returns True on non-TPU backends so tests and benchmarks run here while the
same call sites compile to Mosaic on real hardware.

Tiling policy (DESIGN.md §1): the CPU-level partition of the paper becomes
the VMEM block.  Rows-per-block is the paper's 2^i rule aligned to the
(8, 128) sublane×lane vector shape; column tiles are multiples of 128 so
MXU matmul dims stay hardware-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128
SUBLANE = 8


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_block_rows(n_rows: int, n_cols: int, dtype,
                    vmem_budget: int = 4 * 1024 * 1024,
                    n_live: int = 2) -> int:
    """Rows per VMEM block: largest power of two whose working set
    (n_live copies of a rows×cols tile) fits the VMEM budget."""
    bytes_per_row = max(1, n_cols) * jnp.dtype(dtype).itemsize * n_live
    rows = max(SUBLANE, vmem_budget // bytes_per_row)
    rows = 1 << (int(rows).bit_length() - 1)
    return int(min(rows, max(SUBLANE, n_rows)))


def pad_rows(x, multiple: int, value=0.0):
    """Pad the leading dim to a multiple; returns (padded, original_len)."""
    n = x.shape[0]
    target = round_up(n, multiple)
    if target == n:
        return x, n
    pad = [(0, target - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=value), n
