"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically-direct implementation the kernels are
tested against with assert_allclose over shape/dtype sweeps
(tests/test_kernels.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_summary_ref(x):
    x = x.astype(jnp.float32)
    return (x.sum(0), (x * x).sum(0), x.min(0), x.max(0),
            jnp.abs(x).sum(0), (x != 0).astype(jnp.float32).sum(0))


def gram_ref(x):
    x = x.astype(jnp.float32)
    return x.T @ x


def xty_ref(x, y):
    return x.astype(jnp.float32).T @ y.astype(jnp.float32)


def wgram_ref(x, w):
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32).reshape(-1, 1)
    return (xf * wf).T @ xf


def spmm_dense_ref(cols, vals, ncol):
    """ELL slab → dense f32 (rows, ncol): the densify every SpMM oracle
    shares (padding entries are (col=0, val=0), neutral under add)."""
    rows, kmax = cols.shape
    r = jnp.repeat(jnp.arange(rows), kmax)
    out = jnp.zeros((rows, ncol), jnp.float32)
    return out.at[r, cols.reshape(-1)].add(
        vals.reshape(-1).astype(jnp.float32))


def spmm_gram_ref(cols, vals, ncol):
    x = spmm_dense_ref(cols, vals, ncol)
    return x.T @ x


def spmm_xty_ref(cols, vals, y, ncol):
    return spmm_dense_ref(cols, vals, ncol).T @ y.astype(jnp.float32)


def spmm_wgram_ref(cols, vals, w, ncol):
    x = spmm_dense_ref(cols, vals, ncol)
    return (x * w.astype(jnp.float32).reshape(-1, 1)).T @ x


def kmeans_assign_ref(x, centers):
    x = x.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    d = ((x[:, None, :] - c[None]) ** 2).sum(-1)            # (n, k)
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    k = c.shape[0]
    onehot = jnp.eye(k, dtype=jnp.float32)[lab]
    sums = onehot.T @ x
    cnts = onehot.sum(0)
    wss = d.min(1).sum()[None]
    return lab, sums, cnts, wss


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Naive softmax attention over (BH, S, D)."""
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        sq, sk = s.shape[1], s.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
